"""PowerHierarchy: per-node conservation under random topologies and
depths, bit-parity of the two-level path with the legacy RackHierarchy math,
frac-vector vs legacy 2-tuple publishing, HierarchySpec round-trips, and
tree-scope controller recursion (determinism + worker-invariance)."""

import numpy as np
import pytest

from repro.core.hierarchy import PowerHierarchy
from repro.experiments import (
    ControllerSpec,
    FleetSpec,
    HierarchySpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TrafficSpec,
    get_scenario,
    run_experiment,
)
from repro.provisioning import EnsembleSpec, run_ensemble


# ------------------------------------------------------- random topologies
def _random_hierarchy(rng: np.random.Generator) -> PowerHierarchy:
    """A random uniform tree: depth 1-4, fan-outs 1-4, random budgets."""
    depth = int(rng.integers(1, 5))
    shape = tuple(int(rng.integers(1, 5)) for _ in range(depth))
    n_rows = int(np.prod(shape))
    budgets = rng.uniform(50.0, 500.0, n_rows)
    fracs = {}
    if depth >= 2 and rng.random() < 0.5:
        # derate a random non-root interior node
        d = int(rng.integers(1, depth))
        digits = [int(rng.integers(0, shape[k])) for k in range(d)]
        fracs["/".join(map(str, digits))] = float(rng.uniform(0.5, 0.9))
    return PowerHierarchy.from_shape(shape, budgets, budget_fracs=fracs)


def test_property_per_node_conservation_random_topologies():
    """For 25 random trees: budget conservation (every interior node's
    budget == sum of children), watts conservation through node_w/fold_w,
    and leaf coverage (the root sees every row exactly once)."""
    rng = np.random.default_rng(42)
    for trial in range(25):
        h = _random_hierarchy(rng)
        assert h.conservation_errors() == [], f"trial {trial}"
        row_w = rng.uniform(0.0, 400.0, h.n_leaves)
        node = h.node_w(row_w)
        for i in range(h.n_leaves, h.n_nodes):
            np.testing.assert_allclose(node[i], node[h.children[i]].sum(),
                                       rtol=1e-12)
        np.testing.assert_allclose(node[h.root], row_w.sum(), rtol=1e-12)
        power = rng.uniform(0.0, 400.0, (6, h.n_leaves))
        folded = h.fold_w(power)
        for i in range(h.n_leaves, h.n_nodes):
            np.testing.assert_allclose(folded[:, i],
                                       folded[:, h.children[i]].sum(axis=1),
                                       rtol=1e-12)
        # every leaf under the root exactly once
        assert np.array_equal(h.subtree_leaves(h.root),
                              np.arange(h.n_leaves))


def test_property_publish_vector_depth_and_order():
    """The published frac vector is level-indexed: one entry per ancestor,
    nearest (rack) first, root last — and each entry is that node's watts
    over its budget."""
    class Row:
        group_fracs = (None, None)

    rng = np.random.default_rng(7)
    for _ in range(10):
        h = _random_hierarchy(rng)
        rows = [Row() for _ in range(h.n_leaves)]
        row_w = rng.uniform(10.0, 300.0, h.n_leaves)
        frac = h.publish(rows, row_w)
        node = h.node_w(row_w)
        np.testing.assert_allclose(frac, node / h.node_budget_w, rtol=1e-12)
        for i, r in enumerate(rows):
            assert len(r.group_fracs) == len(h.ancestors[i])
            for lv, a in enumerate(h.ancestors[i]):
                assert r.group_fracs[lv] == float(frac[a])
            assert r.group_fracs[-1] == float(frac[h.root])


def test_two_level_fold_bit_parity_with_legacy_math():
    """Acceptance: the PowerHierarchy fold of a two-level tree reproduces
    the pre-refactor RackHierarchy expressions bit for bit (np.array_equal,
    not allclose) — rows, ragged last rack, and the direct all-rows cluster
    sum included."""
    rng = np.random.default_rng(3)
    # the wide cases (> 8 rows per rack / > 8 rows total) exercise the
    # regime where numpy's pairwise reduction diverges from sequential
    # accumulation — exactly where naive folds break bit-parity
    for n_rows, rpr in ((4, 2), (5, 2), (9, 4), (6, 3), (3, 1), (20, 10),
                        (24, 12), (13, 13)):
        row_b = rng.uniform(100.0, 400.0, n_rows)
        h = PowerHierarchy.two_level(row_b, rows_per_rack=rpr)
        rack_of = np.asarray([i // rpr for i in range(n_rows)])
        n_racks = int(rack_of[-1]) + 1
        rack_b = np.asarray([float(row_b[rack_of == k].sum())
                             for k in range(n_racks)])
        cluster_b = float(rack_b.sum())
        power = rng.uniform(0.0, 500.0, (11, n_rows))
        # legacy RackHierarchy.fold, verbatim
        row_frac = power / row_b[None, :]
        rack_w = np.zeros((11, n_racks))
        for k in range(n_racks):
            rack_w[:, k] = power[:, rack_of == k].sum(axis=1)
        rack_frac = rack_w / rack_b[None, :]
        cluster_frac = power.sum(axis=1) / cluster_b
        folded = h.fold(power)
        assert np.array_equal(folded[:, :n_rows], row_frac)
        assert np.array_equal(folded[:, h.leaf_parents], rack_frac)
        assert np.array_equal(folded[:, h.root], cluster_frac)
        # legacy publish_group_fracs, verbatim (np.add.at accumulation)
        class Row:
            group_fracs = (None, None)
        rows = [Row() for _ in range(n_rows)]
        row_w = rng.uniform(0.0, 500.0, n_rows)
        frac = h.publish(rows, row_w)
        rw = np.zeros(n_racks)
        np.add.at(rw, rack_of, row_w)
        legacy_rack = rw / rack_b
        legacy_cluster = float(row_w.sum() / cluster_b)
        for i, r in enumerate(rows):
            assert r.group_fracs == (float(legacy_rack[rack_of[i]]),
                                     legacy_cluster)
        assert float(frac[h.root]) == legacy_cluster


def test_row_group_fracs_legacy_two_tuple_property():
    """RowSimulator.group_fracs stays a (rack, cluster) 2-tuple view of the
    level-indexed vector, whatever the tree depth."""
    from repro.core.simulator import RowSimulator
    row = RowSimulator.__new__(RowSimulator)
    row._group_frac_vec = (None, None)
    assert row.group_fracs == (None, None)
    row.group_fracs = (0.5, 0.6)  # legacy writer
    assert row.group_fracs == (0.5, 0.6)
    assert row.group_frac_vec == (0.5, 0.6)
    row.group_fracs = (0.5, 0.7, 0.9)  # deep-tree publisher
    assert row.group_fracs == (0.5, 0.9), "nearest level first, root last"
    assert row.group_frac_vec == (0.5, 0.7, 0.9)


def test_invalid_topologies_rejected():
    with pytest.raises(ValueError, match="root"):
        PowerHierarchy([2, 2, -1, -1], [1.0, 1.0, 2.0, 2.0], 2)
    with pytest.raises(ValueError, match="children first"):
        PowerHierarchy([-1, 0, 0], [2.0, 1.0, 1.0], 2)
    with pytest.raises(ValueError):
        PowerHierarchy.from_shape((2, 2), np.ones(3))  # 3 budgets, 4 rows
    with pytest.raises(ValueError, match="childless"):
        # node 1 is interior (n_leaves=1) but nothing hangs under it
        PowerHierarchy([2, 2, -1], [1.0, 1.0, 2.0], 1)
    # derates must be positive finite multipliers: a 0 W budget divides
    # telemetry by zero (and the RowSimulator nominal fallback would
    # silently undo it)
    for bad in (0.0, -0.5, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="positive finite"):
            PowerHierarchy.from_shape((2, 2), np.ones(4),
                                      budget_fracs={"0": bad})
    # an explicit hierarchy excludes the two-level budget arguments
    from repro.experiments.cluster import resolve_row_hierarchy

    class _Row:
        provisioned_w = 100.0

    rows = [_Row(), _Row()]
    h = PowerHierarchy.two_level([100.0, 100.0])
    with pytest.raises(ValueError, match="not both"):
        resolve_row_hierarchy(rows, h, rack_budget_w=[150.0])
    assert resolve_row_hierarchy(rows, h) is h
    with pytest.raises(ValueError, match="leaves"):
        resolve_row_hierarchy(rows + [_Row()], h)


# ------------------------------------------------------------ HierarchySpec
def test_hierarchy_spec_round_trip_and_build():
    sc = get_scenario("site-tree-predictive")
    assert sc.hierarchy is not None
    assert Scenario.from_json(sc.to_json()) == sc
    h = sc.hierarchy.build(np.full(sc.fleet.n_rows, 100.0))
    assert h.n_leaves == sc.fleet.n_rows == sc.hierarchy.n_rows
    assert h.depth == 3
    assert h.conservation_errors() == []
    # the derate propagated down to rack0.1's three rows
    assert np.allclose(h.leaf_budget_w[3:6], 70.0)
    assert np.allclose(h.leaf_budget_w[:3], 100.0)


def test_with_hierarchy_sizes_fleet():
    sc = (get_scenario("fleet-cap-aware")
          .with_hierarchy((2, 2, 2), budget_fracs={"1": 0.8}))
    assert sc.hierarchy.shape == (2, 2, 2)
    assert sc.fleet.n_rows == 8
    assert Scenario.from_json(sc.to_json()) == sc


# ------------------------------------------------- controller tree recursion
def _site_scenario(**kw) -> Scenario:
    base = dict(
        name="hier-test",
        duration_s=1500.0,
        fleet=FleetSpec(n_provisioned=16, added_frac=0.25, n_rows=8),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.9),
        routing=RoutingSpec("cap-aware"),
        controller=ControllerSpec("predictive", interval_s=30.0, scope="tree"),
        hierarchy=HierarchySpec(shape=(2, 2, 2), budget_fracs={"0/1": 0.7}),
        budget="nominal",
        compare_to_reference=False,
    )
    base.update(kw)
    return Scenario(**base)


def test_tree_scope_conserves_every_node():
    o = run_experiment(_site_scenario())
    f = o.fleet
    assert f.n_rebalances > 0, "the derated site must trigger rebalances"
    h = _site_scenario().hierarchy.build(np.ones(8))
    for ev in f.rebalances:
        na, nb = ev.node_budgets_after_w, ev.node_budgets_before_w
        assert na is not None and nb is not None
        for i in range(h.n_leaves, h.n_nodes):
            kids = h.children[i]
            assert abs(float(na[kids].sum()) - float(na[i])) <= 1e-6
        assert float(na[h.root]) == float(nb[h.root]), "root envelope frozen"
        assert ev.moved_w() > 0.0
    # per-tick node budget matrix conserves at every level
    for i in range(h.n_leaves, h.n_nodes):
        kids = h.children[i]
        assert np.allclose(f.node_budget_w[:, kids].sum(axis=1),
                           f.node_budget_w[:, i], atol=1e-3)
    root = f.node_budget_w[:, h.root]
    assert np.allclose(root, root[0], atol=1e-6)


def test_tree_scope_moves_budget_across_racks():
    """The derated rack (node rack0.1, rows 2-3) must gain *interior* budget
    from its sibling rack / the other PDU set — motion a rack-scope
    controller structurally cannot produce."""
    o = run_experiment(_site_scenario())
    f = o.fleet
    names = list(f.node_names)
    derated = names.index("rack0.1")
    sibling = names.index("rack0.0")
    assert float(f.node_budget_w[:, derated].max()) > \
        float(f.node_budget_w[0, derated])
    assert float(f.node_budget_w[:, sibling].min()) < \
        float(f.node_budget_w[0, sibling])
    # rack-scope on the same scenario never moves interior budgets
    o2 = run_experiment(_site_scenario(
        controller=ControllerSpec("predictive", interval_s=30.0, scope="rack")))
    nb = o2.fleet.node_budget_w
    assert np.all(nb[:, derated] == nb[0, derated])
    assert np.all(nb[:, sibling] == nb[0, sibling])


def test_tree_scope_static_policy_never_moves():
    o = run_experiment(_site_scenario(
        controller=ControllerSpec("static", scope="tree", interval_s=30.0)))
    assert o.fleet.n_rebalances == 0
    assert np.all(o.fleet.node_budget_w == o.fleet.node_budget_w[0])


def test_tree_recursion_determinism():
    a = run_experiment(_site_scenario())
    b = run_experiment(_site_scenario())
    assert a.result.latencies == b.result.latencies
    assert len(a.fleet.rebalances) == len(b.fleet.rebalances)
    for ea, eb in zip(a.fleet.rebalances, b.fleet.rebalances):
        assert ea.t == eb.t
        assert np.array_equal(ea.node_budgets_after_w, eb.node_budgets_after_w)
    c = run_experiment(_site_scenario(seed=8))
    assert a.result.latencies != c.result.latencies, "seed must matter"


def test_tree_controller_ensemble_worker_invariance():
    """Hierarchy-bearing fleet members are bit-identical across Monte-Carlo
    worker counts (the controller recursion is pure per-member state)."""
    base = _site_scenario(duration_s=1000.0)
    e1 = run_ensemble(EnsembleSpec(base, n_seeds=2, seed0=900, n_workers=1))
    e2 = run_ensemble(EnsembleSpec(base, n_seeds=2, seed0=900, n_workers=2))
    assert np.array_equal(e1.brake_counts, e2.brake_counts)
    for m1, m2 in zip(e1.members, e2.members):
        assert m1.result.latencies == m2.result.latencies
        assert np.array_equal(m1.result.power_w, m2.result.power_w)


def test_site_scenarios_registered():
    from repro.experiments import SITE_SCENARIO_FAMILY
    for name in SITE_SCENARIO_FAMILY:
        sc = get_scenario(name)
        assert sc.hierarchy is not None and sc.routing is not None
        assert sc.hierarchy.n_rows == sc.fleet.n_rows
        assert Scenario.from_json(sc.to_json()) == sc
    assert get_scenario("site-tree-predictive").controller.scope == "tree"


def test_shed_tokens_admission_registered_and_metered():
    """The token-budget admission controller sheds a bounded token slice of
    LP during an emergency (non-boolean), never HP, and resets when the
    emergency clears."""
    from repro.core.simulator import Request
    from repro.fleet import ShedTokenBudget, build_admission
    from repro.fleet.router import FleetView

    adm = build_admission("shed-tokens", {"relief_tokens_per_s": 100.0,
                                          "burst_tokens": 300.0})
    assert isinstance(adm, ShedTokenBudget) and adm.needs_view

    def req(rid, prio="low", tokens=200):
        return Request(t_arrival=0.0, wl=0, prompt=64, out_tokens=tokens,
                       priority=prio, rid=rid)

    calm = FleetView(t=0.0, cluster_frac=0.5, n_braked=0)
    hot = lambda t: FleetView(t=t, cluster_frac=0.99, n_braked=0)
    assert adm.admit(req(0), calm)
    # emergency opens: burst debt of 300 tokens -> sheds 2 x 200-token LP
    # requests (debt 300 -> 100 -> 0 plus accrual), then admits again
    assert not adm.admit(req(1), hot(10.0))
    assert not adm.admit(req(2), hot(10.5))
    assert adm.admit(req(3), hot(10.6)), "debt paid: metered, not boolean"
    # HP is never shed, even with outstanding debt
    adm2 = build_admission("shed-tokens", {})
    assert adm2.admit(req(4, prio="high"), hot(20.0))
    # emergency clears -> debt resets
    assert adm.admit(req(5), calm)


def test_shed_tokens_fleet_run_sheds_fewer_than_shed_lp():
    """On the emergency-heavy fleet-rr-shed scenario, token-metered shedding
    drops less LP goodput than boolean shed-lp on the same trace, and sheds
    only LP."""
    base = get_scenario("fleet-rr-shed").with_(duration_s=1800.0,
                                               compare_to_reference=False)
    lp = run_experiment(base)
    tok = run_experiment(base.with_(routing=RoutingSpec(
        "round-robin", admission="shed-tokens",
        admission_params={"shed_above": 0.97})))
    assert tok.fleet.n_shed.get("high", 0) == 0
    assert lp.fleet.n_shed_total > 0, "scenario must actually shed"
    assert 0 < tok.fleet.n_shed_total <= lp.fleet.n_shed_total
    # conservation still exact
    assert tok.fleet.n_admitted + tok.fleet.n_shed_total == tok.fleet.n_offered
