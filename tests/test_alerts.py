"""Alerting engine: spec validation, hysteresis, bind-time target checks,
recorder emission, and the zero-perturbation contract (repro.obs.alerts)."""

import numpy as np
import pytest

from repro.core.hierarchy import PowerHierarchy
from repro.obs.alerts import (
    ALERT_BUILDERS,
    ANY_NODE,
    AlertEngine,
    AlertSpec,
    coerce_alerts,
    default_alert_pack,
)
from repro.obs.metrics import MetricsRecorder, recording

TICK = 2.0


# ----------------------------------------------------------- spec validation

def test_spec_unknown_kind_rejected():
    with pytest.raises(ValueError, match="unknown kind"):
        AlertSpec("not-a-rule")


def test_spec_engage_below_release_rejected():
    with pytest.raises(ValueError, match="engage must be >= release"):
        AlertSpec("cap-proximity", engage=0.5, release=0.9)


def test_spec_projected_requires_root_target():
    with pytest.raises(ValueError, match="root slope"):
        AlertSpec("cap-proximity", target="pdu0", projected=True)


def test_spec_rate_rules_are_fleet_wide():
    with pytest.raises(ValueError, match="fleet-wide"):
        AlertSpec("brake-storm", target="row0", engage=5.0)
    with pytest.raises(ValueError, match="fleet-wide"):
        AlertSpec("slo-burn", target="row0", engage=0.1, release=0.0)


def test_spec_conservation_rejects_any_node():
    with pytest.raises(ValueError):
        AlertSpec("conservation-violation", target=ANY_NODE)


def test_spec_auto_name_and_registry():
    s = AlertSpec("cap-proximity", target="pdu0")
    assert s.name == "cap-proximity:pdu0"
    assert AlertSpec("brake-storm", engage=5.0).name == "brake-storm"
    assert set(ALERT_BUILDERS) == {
        "cap-proximity", "brake-storm", "slo-burn",
        "conservation-violation", "fault-active"}


def test_spec_dict_round_trip():
    s = AlertSpec("slo-burn", engage=0.1, release=0.01, window_s=120.0,
                  for_ticks=3)
    assert AlertSpec.from_dict(s.to_dict()) == s
    assert coerce_alerts([s.to_dict()]) == (s,)
    assert coerce_alerts(None) is None


def test_default_pack_is_valid_and_named_uniquely():
    pack = default_alert_pack()
    names = [s.name for s in pack]
    assert len(names) == len(set(names))
    kinds = {s.kind for s in pack}
    assert kinds == set(ALERT_BUILDERS)


def test_scenario_carries_alerts_through_json():
    from repro.experiments.scenario import Scenario, get_scenario
    sc = get_scenario("chaos-noop")
    assert sc.alerts  # the chaos family ships the default pack
    assert Scenario.from_json(sc.to_json()) == sc
    cleared = sc.with_alerts(None)
    assert cleared.alerts is None


# ------------------------------------------------- engine against a stub fleet

class _Policy:
    def __init__(self):
        self.braked = False


class _Row:
    def __init__(self):
        self.policy = _Policy()


class _StubChaos:
    def __init__(self, n=0):
        self.n = n

    def n_active_derates(self):
        return self.n


class _StubFleet:
    """The attribute surface AlertEngine reads: hierarchy, rows (brake
    flags), shed/offered counters, row liveness, chaos."""

    def __init__(self, h):
        self.hierarchy = h
        self.rows = [_Row() for _ in range(h.n_leaves)]
        self.n_shed = {"high": 0, "low": 0}
        self.row_alive = np.ones(h.n_leaves, dtype=bool)
        self.chaos = None
        self.n_processed = 0


def _site():
    # 4 rows of 100 W under 2 PDUs (200 W each) and a 400 W site root
    return PowerHierarchy.from_shape((2, 2), [100.0] * 4)


def _tick(engine, fleet, t, row_w):
    h = fleet.hierarchy
    leaf = h.node_budget_w[:h.n_leaves]
    interior = h.node_budget_w[h.n_leaves:]
    engine.on_tick(t, fleet, np.asarray(row_w, dtype=float), leaf, interior)


def _engine(fleet, *specs):
    e = AlertEngine(specs, tick_s=TICK)
    e.bind(fleet)
    return e


def test_engine_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate alert names"):
        AlertEngine([AlertSpec("cap-proximity"), AlertSpec("cap-proximity")],
                    tick_s=TICK)


def test_bind_rejects_unknown_target():
    f = _StubFleet(_site())
    e = AlertEngine([AlertSpec("cap-proximity", target="pdu9")], tick_s=TICK)
    with pytest.raises(ValueError, match="no hierarchy node named"):
        e.bind(f)


def test_bind_rejects_leaf_conservation_target():
    f = _StubFleet(_site())
    e = AlertEngine([AlertSpec("conservation-violation", target="row0")],
                    tick_s=TICK)
    with pytest.raises(ValueError, match="interior node"):
        e.bind(f)


def test_hysteresis_engage_release_cycle():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("cap-proximity", target="pdu0", engage=0.9,
                             release=0.8, for_ticks=2))
    quiet = [10.0, 10.0, 10.0, 10.0]
    hot = [95.0, 95.0, 0.0, 0.0]      # pdu0 at 0.95
    band = [85.0, 85.0, 0.0, 0.0]     # 0.85: inside the hysteresis band
    cool = [70.0, 70.0, 0.0, 0.0]     # 0.70: below release
    _tick(e, f, 2.0, quiet)
    _tick(e, f, 4.0, hot)             # streak 1 of 2: no event yet
    assert e.events == []
    _tick(e, f, 6.0, hot)             # streak 2: engage
    assert [(a.phase, a.t) for a in e.events] == [("engage", 6.0)]
    assert e.n_active == 1
    _tick(e, f, 8.0, band)            # in-band: must NOT release (no flap)
    _tick(e, f, 10.0, band)
    assert len(e.events) == 1
    _tick(e, f, 12.0, cool)           # streak 1 of 2
    _tick(e, f, 14.0, cool)           # streak 2: release
    assert [(a.phase, a.t) for a in e.events] == [("engage", 6.0),
                                                  ("release", 14.0)]
    assert e.n_active == 0
    eng, rel = e.events
    assert eng.value == pytest.approx(0.95)
    assert eng.threshold == 0.9 and rel.threshold == 0.8


def test_hysteresis_streak_resets_on_dip():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("cap-proximity", target="pdu0", engage=0.9,
                             release=0.8, for_ticks=2))
    hot, quiet = [95.0, 95.0, 0, 0], [10.0, 10.0, 10, 10]
    _tick(e, f, 2.0, hot)
    _tick(e, f, 4.0, quiet)  # dip resets the engage streak
    _tick(e, f, 6.0, hot)
    assert e.events == []    # never held for 2 consecutive ticks


def test_any_node_tracks_worst():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("cap-proximity", target=ANY_NODE, engage=1.0,
                             release=0.5))
    _tick(e, f, 2.0, [101.0, 0.0, 0.0, 0.0])  # row0 over its own budget
    assert [(a.phase, a.t) for a in e.events] == [("engage", 2.0)]
    assert e.events[0].value == pytest.approx(1.01)


def test_brake_storm_counts_edges_in_window():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("brake-storm", engage=2.0, release=0.5,
                             window_s=4.0))  # 2-tick window
    w = [10.0] * 4
    _tick(e, f, 2.0, w)
    f.rows[0].policy.braked = True
    f.rows[1].policy.braked = True
    _tick(e, f, 4.0, w)  # 2 edges this tick -> window sum 2 -> engage
    assert [(a.phase, a.t) for a in e.events] == [("engage", 4.0)]
    f.rows[0].policy.braked = False
    f.rows[1].policy.braked = False
    _tick(e, f, 6.0, w)   # 2 more edges: stays active
    _tick(e, f, 8.0, w)   # window now [2, 0] -> 2 >= release? no: v=2>0
    _tick(e, f, 10.0, w)  # window [0, 0] -> release
    assert e.events[-1].phase == "release" and e.events[-1].t == 10.0


def test_slo_burn_ratio():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("slo-burn", engage=0.10, release=0.0,
                             window_s=4.0))
    w = [10.0] * 4
    f.n_processed = 100
    _tick(e, f, 2.0, w)           # offered 100, shed 0
    assert e.events == []
    f.n_processed, f.n_shed = 200, {"high": 30, "low": 0}
    _tick(e, f, 4.0, w)           # window: shed 30 / offered 200 = 0.15
    assert [(a.phase, a.t) for a in e.events] == [("engage", 4.0)]
    assert e.events[0].value == pytest.approx(0.15)


def test_conservation_violation_watchdog():
    h = _site()
    f = _StubFleet(h)
    e = _engine(f, AlertSpec("conservation-violation", engage=1.0,
                             release=0.5))
    leaf = h.node_budget_w[:h.n_leaves]
    good = h.node_budget_w[h.n_leaves:]
    _tick(e, f, 2.0, [10.0] * 4)
    assert e.events == []  # planner-shaped budgets conserve exactly
    bad = good.copy()
    bad[0] -= 50.0  # pdu0 no longer the sum of its rows
    e.on_tick(4.0, f, np.full(4, 10.0), leaf, bad)
    assert [(a.phase, a.t) for a in e.events] == [("engage", 4.0)]
    assert e.events[0].value == pytest.approx(50.0)


def test_fault_active_ground_truth():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("fault-active", engage=0.5, release=0.5))
    w = [10.0] * 4
    _tick(e, f, 2.0, w)
    assert e.events == []
    f.row_alive[2] = False
    f.chaos = _StubChaos(1)
    _tick(e, f, 4.0, w)
    assert e.events[0].phase == "engage"
    assert e.events[0].value == 2.0  # fenced row + active derate
    f.row_alive[2] = True
    f.chaos = None
    _tick(e, f, 6.0, w)
    assert e.events[-1].phase == "release"


def test_projected_rule_leads_instantaneous():
    f = _StubFleet(_site())
    e = _engine(f, AlertSpec("cap-proximity", engage=0.9, release=0.5,
                             projected=True))
    # root ramping at 0.005/s: projection (40 s ahead) crosses 0.9 while
    # the instantaneous fraction is still ~0.2 below it
    t, frac = 0.0, 0.4
    while frac < 0.72:
        t += TICK
        frac += 0.005 * TICK
        per_row = frac * 400.0 / 4.0
        _tick(e, f, t, [per_row] * 4)
    assert [a.phase for a in e.events] == ["engage"]
    assert float(e.stream.node_frac[-1]) < 0.9  # fired ahead of the cap


def test_engine_mirrors_transitions_into_recorder():
    f = _StubFleet(_site())
    rec = MetricsRecorder()
    with recording(rec):
        e = _engine(f, AlertSpec("cap-proximity", target="pdu0", engage=0.9,
                                 release=0.8))
        _tick(e, f, 2.0, [95.0, 95.0, 0.0, 0.0])
        _tick(e, f, 4.0, [10.0, 10.0, 0.0, 0.0])
    evs = rec.snapshot().events_of("alert")
    assert [ev.kind for ev in evs] == ["alert_engage", "alert_release"]
    lab = evs[0].labels_dict()
    assert lab["alert"] == "cap-proximity:pdu0"
    assert lab["rule"] == "cap-proximity"
    assert lab["target"] == "pdu0"
    assert float(lab["value"]) == pytest.approx(0.95)
    rel = evs[1].labels_dict()
    assert float(rel["engaged_s"]) == pytest.approx(2.0)
    assert rec.snapshot().counter_total("alert_transitions_total") == 2.0


# ------------------------------------------------------- zero perturbation

def test_alerts_do_not_perturb_the_fleet():
    """The tier-1 contract: an engine emitting real transitions leaves the
    simulation bit-identical to an alerts-off run."""
    from repro.experiments import get_scenario, run_experiment
    sc = get_scenario("chaos-noop").with_(duration_s=1800.0,
                                          compare_to_reference=False)
    # a hair-trigger rule so the engine engages immediately and stays busy
    noisy = sc.with_alerts([
        AlertSpec("cap-proximity", engage=0.01, release=0.0),
        AlertSpec("brake-storm", engage=1.0, release=0.0, window_s=60.0),
    ])
    on = run_experiment(noisy)
    off = run_experiment(sc.with_alerts(None))
    assert on.fleet.n_alert_events > 0
    assert off.fleet.alert_events == []
    assert on.result.latencies == off.result.latencies
    assert on.fleet.decisions == off.fleet.decisions
    assert np.array_equal(on.fleet.cluster_power_frac,
                          off.fleet.cluster_power_frac)
    assert np.array_equal(on.fleet.node_budget_w, off.fleet.node_budget_w)
    assert on.fleet.n_shed == off.fleet.n_shed
    eng = on.fleet.alerts_of(phase="engage")
    assert eng and all(a.phase == "engage" for a in eng)
