"""Documentation stays true: the generated scenario reference matches the
live registry, and the docs/README cross-link structure exists."""

import os
import sys

import pytest

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _gen_module():
    sys.path.insert(0, os.path.join(ROOT, "tools"))
    try:
        import gen_scenario_docs
    finally:
        sys.path.pop(0)
    return gen_scenario_docs


def test_scenario_docs_in_sync_with_registry():
    """Acceptance: docs/scenarios.md is exactly what the generator emits for
    the current registry (regenerate with tools/gen_scenario_docs.py)."""
    gen = _gen_module()
    path = os.path.join(ROOT, "docs", "scenarios.md")
    assert os.path.exists(path), "docs/scenarios.md missing; run the generator"
    with open(path) as fh:
        on_disk = fh.read()
    assert on_disk == gen.generate(), (
        "docs/scenarios.md is out of sync with the scenario registry; "
        "run: PYTHONPATH=src python tools/gen_scenario_docs.py")


def test_registry_docs_in_sync_with_registries():
    """Acceptance: docs/registries.md is exactly what the generator emits
    for the live policy/router/admission/rebalance/generator registries."""
    gen = _gen_module()
    path = os.path.join(ROOT, "docs", "registries.md")
    assert os.path.exists(path), "docs/registries.md missing; run the generator"
    with open(path) as fh:
        on_disk = fh.read()
    assert on_disk == gen.generate_registries(), (
        "docs/registries.md is out of sync with the live registries; "
        "run: PYTHONPATH=src python tools/gen_scenario_docs.py")


def test_registry_docs_cover_every_registered_name():
    import repro.provisioning  # noqa: F401  (registers the mc-* generators)
    from repro.chaos import FAULT_EVENT_BUILDERS
    from repro.core.traces import list_occupancy_generators
    from repro.experiments.scenario import POLICY_BUILDERS
    from repro.fleet.controller import REBALANCE_BUILDERS
    from repro.fleet.router import ADMISSION_BUILDERS, ROUTER_BUILDERS
    with open(os.path.join(ROOT, "docs", "registries.md")) as fh:
        text = fh.read()
    for registry in (POLICY_BUILDERS, ROUTER_BUILDERS, ADMISSION_BUILDERS,
                     REBALANCE_BUILDERS, FAULT_EVENT_BUILDERS):
        for name in registry:
            assert f"`{name}`" in text, f"registry entry {name!r} missing"
    for name in list_occupancy_generators():
        assert f"`{name}`" in text, f"generator {name!r} missing from docs"


def test_scenario_docs_cover_every_registered_scenario():
    import repro.provisioning  # noqa: F401  (registers mc-* scenarios)
    from repro.experiments import list_scenarios
    with open(os.path.join(ROOT, "docs", "scenarios.md")) as fh:
        text = fh.read()
    for name in list_scenarios():
        assert f"`{name}`" in text, f"scenario {name!r} missing from docs"


@pytest.mark.parametrize("path", [
    "README.md",
    os.path.join("docs", "architecture.md"),
    os.path.join("docs", "quickstart.md"),
    os.path.join("docs", "scenarios.md"),
    os.path.join("docs", "registries.md"),
])
def test_docs_pages_exist(path):
    assert os.path.exists(os.path.join(ROOT, path))


def test_readme_links_docs_and_design():
    with open(os.path.join(ROOT, "README.md")) as fh:
        text = fh.read()
    for target in ("docs/architecture.md", "docs/quickstart.md",
                   "docs/scenarios.md", "DESIGN.md"):
        assert target in text, f"README.md must link {target}"
