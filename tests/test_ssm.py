"""SSD (Mamba2) correctness: chunked matmul form vs sequential oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.kernels.ref import ssd_reference
from repro.models import ssm
from repro.models.param import init_params

KEY = jax.random.key(42)


def _cfg(chunk=16, d_state=16, headdim=16, d_model=64):
    return smoke_config("mamba2-370m").replace(
        ssm_chunk=chunk, ssm_d_state=d_state, ssm_headdim=headdim,
        d_model=d_model, dtype="float32", param_dtype="float32")


@pytest.mark.parametrize("S,chunk", [(16, 16), (64, 16), (40, 16), (128, 32), (7, 16)])
def test_chunked_ssd_matches_sequential(S, chunk):
    """Full pipeline check: ssm_forward (chunked) == decode recurrence rolled
    over the sequence token by token."""
    cfg = _cfg(chunk=chunk)
    p = init_params(ssm.ssm_specs(cfg), KEY)
    B = 2
    x = jax.random.normal(jax.random.fold_in(KEY, S), (B, S, cfg.d_model), jnp.float32) * 0.5

    y_chunked, (state_c, tails_c) = ssm.ssm_forward(cfg, p, x, return_state=True)

    d_in, H, G, N = ssm.ssm_dims(cfg)
    state = jnp.zeros((B, H, N, cfg.ssm_headdim), jnp.float32)
    tails = {k: jnp.zeros((B, cfg.ssm_conv_width - 1, dim), jnp.float32)
             for k, dim in (("x", d_in), ("B", G * N), ("C", G * N))}
    ys = []
    for t in range(S):
        y_t, (state, tails) = ssm.ssm_decode(cfg, p, x[:, t:t + 1], state, tails)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)

    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state_c), np.asarray(state),
                               atol=1e-4, rtol=1e-3)
    for k in tails:
        np.testing.assert_allclose(np.asarray(tails_c[k]), np.asarray(tails[k]),
                                   atol=1e-5)


def test_ssd_core_vs_oracle():
    """The SSD math itself (isolated from projections/conv) vs ref oracle."""
    B, S, H, P, G, N, Q = 2, 64, 4, 16, 1, 16, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    D = jnp.ones((H,))
    y_ref, final_ref = ssd_reference(x, dt, A, Bm, Cm, D)

    # chunked evaluation via the same algebra as ssm_forward's core
    C_ = S // Q
    Xc = x.reshape(B, C_, Q, H, P)
    dtc = dt.reshape(B, C_, Q, H)
    Bc = Bm.reshape(B, C_, Q, G, N)
    Cc = Cm.reshape(B, C_, Q, G, N)
    dA = dtc * A[None, None, None, :]
    cs = jnp.cumsum(dA, axis=2)
    rep = H // G
    Lexp = cs[:, :, :, None, :] - cs[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(Lexp), 0.0)
    CB = jnp.repeat(jnp.einsum("bcqgn,bckgn->bcqkg", Cc, Bc), rep, axis=-1)
    Y = jnp.einsum("bcqkh,bckhp->bcqhp", CB * L * dtc[:, :, None, :, :], Xc)
    decay_states = jnp.exp(cs[:, :, -1:, :] - cs)
    Bh = jnp.repeat(Bc, rep, axis=3)
    states = jnp.einsum("bckhn,bckh,bckhp->bchnp", Bh, decay_states * dtc, Xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])

    def body(s_prev, inp):
        st_c, dec_c = inp
        return s_prev * dec_c[:, :, None, None] + st_c, s_prev

    final, prev = jax.lax.scan(body, jnp.zeros((B, H, N, P)),
                               (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)
    Ch = jnp.repeat(Cc, rep, axis=3)
    Y += jnp.einsum("bcqhn,bchnp->bcqhp", Ch * jnp.exp(cs)[..., None], prev)
    Y += D[None, None, None, :, None] * Xc
    y = Y.reshape(B, S, H, P)

    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_ref), atol=1e-4, rtol=1e-3)


def test_ssm_prefill_continuation():
    """state returned by prefill continues exactly (prefill(S) == prefill(S/2) + roll)."""
    cfg = _cfg()
    p = init_params(ssm.ssm_specs(cfg), KEY)
    B, S = 1, 32
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, cfg.d_model)) * 0.5
    y_full = ssm.ssm_forward(cfg, p, x)
    y_a, (state, tails) = ssm.ssm_forward(cfg, p, x[:, :S // 2], return_state=True)
    ys = [y_a]
    for t in range(S // 2, S):
        y_t, (state, tails) = ssm.ssm_decode(cfg, p, x[:, t:t + 1], state, tails)
        ys.append(y_t)
    y_cont = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_cont), atol=1e-4, rtol=1e-3)
