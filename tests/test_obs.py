"""Observability subsystem: zero-perturbation recording, exact
reconciliation, deterministic export (DESIGN.md §14).

Covers the hard guarantees end to end: recorder-on vs recorder-off
bit-parity on routed-fleet / tree-controller / chaos / Monte-Carlo runs,
brake-edge events reconciling exactly with ``braked_series``, ensemble
traces invariant to the worker count, histogram snapshot/merge algebra,
Prometheus + JSONL + manifest round-trips, the ``--only`` benchmark
selector, the artifact report renderer, and the shared launcher logging."""

import io
import json
import logging
import os

import numpy as np
import pytest

from repro.chaos import FaultEvent, FaultSpec
from repro.experiments import (
    ControllerSpec,
    FleetSpec,
    HierarchySpec,
    PolicySpec,
    RoutingSpec,
    Scenario,
    TrafficSpec,
    run_experiment,
)
from repro.obs.export import (
    EVENTS_NAME,
    METRICS_NAME,
    event_lines,
    prometheus_text,
    read_events,
    read_manifest,
    read_prometheus,
    run_manifest,
    write_artifacts,
    write_events,
)
from repro.obs.metrics import (
    Event,
    Histogram,
    MetricsRecorder,
    NullRecorder,
    get_recorder,
    label_key,
    recording,
    set_recorder,
)
from repro.provisioning import EnsembleSpec, run_ensemble


def _obs_scenario(faults=None, **kw) -> Scenario:
    base = dict(
        name="obs-test",
        duration_s=1500.0,
        fleet=FleetSpec(n_provisioned=16, added_frac=0.25, n_rows=8),
        policy=PolicySpec("polca"),
        traffic=TrafficSpec(occ_peak=0.9),
        routing=RoutingSpec("cap-aware"),
        controller=ControllerSpec("predictive", interval_s=30.0, scope="tree"),
        hierarchy=HierarchySpec(shape=(2, 2, 2)),
        budget="nominal",
        compare_to_reference=False,
        faults=faults,
    )
    base.update(kw)
    return Scenario(**base)


_DERATE = FaultSpec((FaultEvent("node-derate", t=300.0, node="pdu0",
                                factor=0.7, until=1200.0),))


def _run_recorded(scenario):
    rec = MetricsRecorder()
    with recording(rec):
        res = run_experiment(scenario)
    return res, rec.snapshot()


def _assert_bit_identical(off, on):
    assert off.result.latencies == on.result.latencies
    assert off.fleet.decisions == on.fleet.decisions
    assert off.fleet.n_shed == on.fleet.n_shed
    assert np.array_equal(off.fleet.cluster_power_frac,
                          on.fleet.cluster_power_frac)
    assert np.array_equal(off.fleet.row_power_frac, on.fleet.row_power_frac)
    assert off.result.n_brakes == on.result.n_brakes


# ------------------------------------------------------------- recorder core
def test_default_recorder_is_disabled_null():
    rec = get_recorder()
    assert isinstance(rec, NullRecorder) and not rec.enabled
    # every write is a no-op and must not raise
    rec.counter("x", row=1)
    rec.gauge("g", 1.0)
    rec.observe("h", 0.5)
    rec.event("sub", "kind", t=0.0)
    with rec.span("s"):
        pass


def test_recording_context_installs_and_restores():
    rec = MetricsRecorder()
    outer = get_recorder()
    with recording(rec):
        assert get_recorder() is rec
        get_recorder().counter("inside")
    assert get_recorder() is outer
    assert rec.snapshot().counter_total("inside") == 1.0


def test_set_recorder_none_resets_to_null():
    set_recorder(MetricsRecorder())
    try:
        assert get_recorder().enabled
    finally:
        set_recorder(None)
    assert not get_recorder().enabled


# ------------------------------------------------------- bit-parity contract
def test_fleet_bit_parity_recorder_on_vs_off():
    """Acceptance: instrumentation observes, never perturbs — a routed
    tree-controller fleet run is bit-identical with a live recorder."""
    sc = _obs_scenario()
    off = run_experiment(sc)
    on, snap = _run_recorded(sc)
    _assert_bit_identical(off, on)
    # and the trace actually recorded the run: every non-shed routing
    # decision is a dispatch increment, every shed one a shed increment
    n_shed = sum(1 for d in on.fleet.decisions if d.row < 0)
    assert snap.counter_total("fleet_dispatch_total") == \
        len(on.fleet.decisions) - n_shed
    assert snap.counter_total("fleet_shed_total") == n_shed
    assert snap.counter_total("fleet_ticks_total") > 0


def test_chaos_bit_parity_and_fault_transition_events():
    sc = _obs_scenario(faults=_DERATE)
    off = run_experiment(sc)
    on, snap = _run_recorded(sc)
    _assert_bit_identical(off, on)
    # one chaos event per applied fault phase, reconciling with the audit log
    chaos_events = (snap.events_of("chaos", "fault_apply")
                    + snap.events_of("chaos", "fault_restore"))
    assert len(chaos_events) == on.fleet.n_fault_events == 2
    assert snap.counter_total("chaos_fault_transitions_total") == 2


def test_controller_rebalance_events_reconcile():
    on, snap = _run_recorded(_obs_scenario())
    evs = snap.events_of("controller", "rebalance")
    assert len(evs) == on.fleet.n_rebalances
    assert snap.counter_total("controller_rebalance_total") == len(evs)
    if evs:  # label values are canonicalized to strings in the trace
        moved = sum(float(e.labels_dict()["moved_w"]) for e in evs)
        assert moved == pytest.approx(on.fleet.budget_moved_w(), abs=1e-3)


def test_brake_edges_reconcile_with_braked_series():
    on, snap = _run_recorded(_obs_scenario(
        traffic=TrafficSpec(occ_peak=1.0), budget="calibrated"))
    total_edges = 0
    for i, rr in enumerate(on.fleet.row_results):
        s = np.asarray(rr.braked_series, bool)
        prev = np.concatenate([[False], s[:-1]])
        want = (int(np.sum(~prev & s)), int(np.sum(prev & ~s)))
        eng = sum(1 for e in snap.events_of("row", "brake_engage")
                  if e.labels_dict().get("row") == str(i))
        rel = sum(1 for e in snap.events_of("row", "brake_release")
                  if e.labels_dict().get("row") == str(i))
        assert (eng, rel) == want, f"row {i}"
        total_edges += eng + rel
    assert total_edges == snap.counter_total("row_brake_edges_total")


# --------------------------------------------------- Monte-Carlo invariance
def test_ensemble_bit_parity_and_worker_invariant_traces():
    base = _obs_scenario(duration_s=900.0)
    spec = dict(n_seeds=2, seed0=700)
    off = run_ensemble(EnsembleSpec(base, n_workers=1, **spec))
    snaps = []
    for w in (1, 2):
        rec = MetricsRecorder()
        with recording(rec):
            on = run_ensemble(EnsembleSpec(base, n_workers=w, **spec))
        assert on.brake_prob() == off.brake_prob()
        snaps.append(rec.snapshot())
    s1, s2 = snaps
    assert s1.counters == s2.counters
    assert s1.gauges == s2.gauges
    assert s1.hists == s2.hists
    assert s1.events == s2.events
    # per-member shard spans were captured and merged
    assert any(name == "mc/shard" for (name, _) in s1.spans)


# ------------------------------------------------------------ histogram math
def test_histogram_merge_is_concatenation():
    """Property: merge(hist(A), hist(B)) == hist(A ++ B), across random
    draws spanning every bucket regime (sub-min, mid, overflow)."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        a = rng.lognormal(mean=-2.0, sigma=3.0, size=137)
        b = rng.lognormal(mean=1.0, sigma=2.0, size=61)
        ha, hb, hab = Histogram(), Histogram(), Histogram()
        for x in a:
            ha.observe(float(x))
            hab.observe(float(x))
        for x in b:
            hb.observe(float(x))
            hab.observe(float(x))
        m = Histogram()
        m.merge(ha)
        m.merge(hb)
        assert m.counts == hab.counts and m.bounds == hab.bounds
        assert m.count == hab.count == len(a) + len(b)
        # summation order differs (partial sums vs interleaved): approx only
        assert m.sum == pytest.approx(hab.sum, rel=1e-12)


def test_histogram_quantile_and_cumulative():
    h = Histogram()
    for x in np.linspace(0.001, 10.0, 1000):
        h.observe(float(x))
    assert h.count == 1000
    q50, q99 = h.quantile(0.5), h.quantile(0.99)
    assert 0.0 < q50 <= q99
    cum = h.cumulative()
    assert cum == sorted(cum)  # cumulative counts are monotone
    assert cum[-1] == 1000  # everything lands under the top finite bound


def test_snapshot_merge_accumulates():
    r1, r2 = MetricsRecorder(), MetricsRecorder()
    r1.counter("c", k="a")
    r1.gauge("g", 1.0)
    r1.observe("h", 0.1)
    r1.event("s", "e1", t=1.0)
    r2.counter("c", k="a", value=2.0)
    r2.gauge("g", 5.0)
    r2.observe("h", 0.2)
    r2.event("s", "e2", t=2.0)
    s = r1.snapshot()
    s.merge(r2.snapshot())
    assert s.counter_total("c") == 3.0
    assert s.gauges[("g", ())] == 5.0  # per-key max wins
    assert s.hists[("h", ())].count == 2
    assert [e.kind for e in s.events] == ["e1", "e2"]


def test_snapshot_merge_gauges_order_independent():
    """Gauge merge is max-per-key: merging worker snapshots in either
    order yields the same gauges (last-write-wins depended on worker
    scheduling)."""
    r1, r2 = MetricsRecorder(), MetricsRecorder()
    r1.gauge("peak", 3.0)
    r1.gauge("only_a", 1.0)
    r2.gauge("peak", 2.0)
    r2.gauge("only_b", 4.0)
    ab = r1.snapshot().merge(r2.snapshot())
    ba = r2.snapshot().merge(r1.snapshot())
    assert ab.gauges == ba.gauges
    assert ab.gauges[("peak", ())] == 3.0
    assert ab.gauges[("only_a", ())] == 1.0
    assert ab.gauges[("only_b", ())] == 4.0


def test_fast_path_label_keys_match_kwargs_path():
    r1, r2 = MetricsRecorder(), MetricsRecorder()
    r1.counter("c", reason="x", row="3")
    r1.observe("h", 0.5, priority="high")
    r2.counter_k("c", 1.0, label_key({"reason": "x", "row": "3"}))
    r2.observe_k("h", 0.5, (("priority", "high"),))
    assert r1.snapshot().counters == r2.snapshot().counters
    assert r1.snapshot().hists == r2.snapshot().hists


# ------------------------------------------------------------------- export
def test_events_jsonl_roundtrip(tmp_path):
    rec = MetricsRecorder()
    rec.event("row", "brake_engage", t=0.5, row=3)
    rec.event("controller", "rebalance", t=1.0, moved_w=12.5,
              policy="predictive")
    rec.event("chaos", "fault_apply", t=2.0)
    snap = rec.snapshot()
    path = tmp_path / EVENTS_NAME
    with open(path, "w") as f:
        assert write_events(snap, f) == 3
    back = read_events(str(path))
    assert back == snap.events
    assert back[0] == Event(0.5, "row", "brake_engage", (("row", "3"),))
    # deterministic serialization: sorted keys, one JSON object per line
    lines = event_lines(snap)
    assert lines == event_lines(snap)
    assert all(json.loads(ln) for ln in lines)


def test_prometheus_roundtrip(tmp_path):
    rec = MetricsRecorder()
    rec.counter("fleet_dispatch_total", reason='ok "primary"', row="0")
    rec.counter("fleet_dispatch_total", reason="spill\nover", row="1",
                value=2.0)
    rec.gauge("fleet_cluster_power_frac", 0.875)
    rec.observe("row_queue_delay_seconds", 0.25, priority="high")
    with rec.span("mc/run_ensemble", base="obs-test"):
        pass
    snap = rec.snapshot()
    text = prometheus_text(snap)
    path = tmp_path / METRICS_NAME
    path.write_text(text)
    prom = read_prometheus(str(path))
    counters = dict()
    for labels, v in prom["counter"]["fleet_dispatch_total"]:
        counters[labels["reason"]] = v
    assert counters == {'ok "primary"': 1.0, "spill\nover": 2.0}
    assert prom["gauge"]["fleet_cluster_power_frac"][0][1] == 0.875
    # suffixed samples resolve to the declared base TYPE
    hist = prom["histogram"]
    [(labels, n)] = hist["row_queue_delay_seconds_count"]
    assert labels == {"priority": "high"} and n == 1.0
    inf = [v for lb, v in hist["row_queue_delay_seconds_bucket"]
           if lb["le"] == "+Inf"]
    assert inf == [1.0]
    [(labels, n)] = prom["summary"]["mc_run_ensemble_seconds_count"]
    assert labels == {"base": "obs-test"} and n == 1.0
    assert "untyped" not in prom


def test_manifest_and_write_artifacts(tmp_path):
    rec = MetricsRecorder()
    rec.counter("c")
    rec.event("s", "k", t=0.0)
    man = run_manifest(seed=123, scenario="obs-test",
                       argv=["benchmarks.run", "--quick"],
                       extra={"kind": "test"})
    write_artifacts(str(tmp_path), rec.snapshot(), man)
    back = read_manifest(str(tmp_path))
    assert back["seed"] == 123
    assert back["scenario"] == "obs-test"
    assert back["kind"] == "test"
    assert back["numpy"]
    assert (tmp_path / METRICS_NAME).exists()
    assert len(read_events(str(tmp_path / EVENTS_NAME))) == 1


# -------------------------------------------------------- benchmark selector
def test_select_modules_matching_rules():
    from benchmarks.run import MODULES, select_modules

    assert select_modules(None) == list(MODULES)
    assert select_modules("") == list(MODULES)
    # prefix match stops at an underscore boundary
    [m] = select_modules("table2")
    assert m.endswith("table2_cluster_stats")
    # comma list, original MODULES order, deduped
    sel = select_modules("capacity,table2,table2")
    assert [s.rsplit(".", 1)[-1][:8] for s in sel] == \
        [m.rsplit(".", 1)[-1][:8] for m in MODULES
         if m.rsplit(".", 1)[-1].startswith(("table2", "capacity"))]
    assert select_modules("observability") == ["benchmarks.observability"]


def test_select_modules_rejects_unknown_token():
    from benchmarks.run import select_modules

    with pytest.raises(SystemExit, match="matches no benchmark module"):
        select_modules("fig1")  # was the substring footgun: fig13 != fig1
    with pytest.raises(SystemExit, match="known:"):
        select_modules("table2,nope")


# ----------------------------------------------------------- report pipeline
def _synthetic_artifacts(d, ok=True, us=100.0):
    rows = {"r/a": {"us_per_call": us, "derived": "x", "ok": ok},
            "r/b": {"us_per_call": 5.0, "derived": "y", "ok": None}}
    with open(os.path.join(d, "BENCH_mod.json"), "w") as f:
        json.dump({"module": "mod", "rows": rows}, f)
    rec = MetricsRecorder()
    rec.counter("c_total", kind="k")
    rec.event("sub", "kind", t=1.0)
    with rec.span("stage", phase="p"):
        pass
    write_artifacts(d, rec.snapshot(), run_manifest(seed=7))


def test_report_render_and_diff(tmp_path):
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "report", os.path.join(os.path.dirname(__file__), "..",
                               "tools", "report.py"))
    report = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(report)

    old, new = tmp_path / "old", tmp_path / "new"
    old.mkdir(), new.mkdir()
    _synthetic_artifacts(str(old), ok=True, us=100.0)
    _synthetic_artifacts(str(new), ok=False, us=150.0)
    rep = report.render_report(str(old))
    assert "| mod | 2 | 1 | 0 |" in rep
    assert "**seed**: `7`" in rep
    assert "stage" in rep  # span flame summary
    assert "| sub | kind | 1 |" in rep
    diff = report.render_diff(str(old), str(new))
    assert "Regressions" in diff and "r/a" in diff
    assert "+50.0%" in diff
    assert report.main([str(old)]) == 0
    assert report.main([]) == 2


# ---------------------------------------------------------- launcher logging
def test_logging_env_level_and_stream():
    from repro.obs import log as obslog

    buf = io.StringIO()
    old_env = os.environ.get(obslog.ENV_VAR)
    os.environ[obslog.ENV_VAR] = "WARNING"
    try:
        obslog.setup_logging(stream=buf, force=True)
        lg = obslog.get_logger("launch.test")
        assert lg.name == "repro.launch.test"
        lg.info("hidden")
        lg.warning("arch=%s", "t5x")
        assert buf.getvalue() == "arch=t5x\n"  # message-only, print-identical
    finally:
        if old_env is None:
            os.environ.pop(obslog.ENV_VAR, None)
        else:
            os.environ[obslog.ENV_VAR] = old_env
        obslog.setup_logging(force=True)  # restore default stderr handler


def test_launchers_use_shared_logger():
    import repro.launch.dryrun as dryrun
    import repro.launch.serve as serve
    import repro.launch.train as train

    for mod in (dryrun, serve, train):
        assert isinstance(mod.log, logging.Logger)
        assert mod.log.name.startswith("repro.")
