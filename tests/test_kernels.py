"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _qkv(i, B, Sq, Skv, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, i), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    return q, k, v


FLASH_CASES = [
    # (B, Sq, Skv, H, KV, hd, dtype, causal, window, softcap, bq, bk)
    (2, 128, 128, 4, 2, 64, jnp.bfloat16, True, 0, 0.0, 64, 64),
    (2, 128, 128, 4, 2, 64, jnp.float32, True, 0, 0.0, 64, 64),
    (1, 256, 256, 8, 8, 64, jnp.bfloat16, True, 64, 0.0, 64, 64),
    (1, 256, 256, 8, 4, 64, jnp.bfloat16, True, 100, 0.0, 64, 32),
    (1, 128, 128, 4, 1, 128, jnp.bfloat16, True, 0, 50.0, 64, 64),
    (1, 128, 128, 4, 1, 128, jnp.float32, True, 0, 30.0, 32, 64),
    (2, 64, 192, 4, 2, 64, jnp.bfloat16, True, 0, 0.0, 64, 64),  # q_offset
    (1, 128, 128, 2, 2, 32, jnp.float32, False, 0, 0.0, 64, 64),  # bidir
    (1, 64, 64, 16, 2, 64, jnp.bfloat16, True, 0, 0.0, 64, 64),  # G=8
    (1, 256, 256, 4, 4, 256, jnp.bfloat16, True, 128, 30.0, 128, 128),  # gemma2-like
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: f"{c[1]}x{c[2]}h{c[3]}kv{c[4]}d{c[5]}{np.dtype(c[6]).name}c{int(c[7])}w{c[8]}s{c[9]}")
def test_flash_attention_vs_ref(case):
    B, Sq, Skv, H, KV, hd, dtype, causal, window, softcap, bq, bk = case
    q, k, v = _qkv(hash(case[:6]) % 1000, B, Sq, Skv, H, KV, hd, dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=Skv - Sq,
                              block_q=bq, block_k=bk, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=tol, rtol=tol)


DECODE_CASES = [
    # (B, T, H, KV, hd, valid_len, softcap, bk)
    (2, 512, 8, 2, 64, 300, 0.0, 128),
    (1, 1024, 4, 4, 128, 1024, 0.0, 256),
    (3, 512, 16, 8, 64, 17, 0.0, 128),
    (1, 256, 4, 1, 64, 128, 50.0, 64),
    (2, 512, 2, 2, 256, 511, 0.0, 512),
    (1, 128, 32, 4, 64, 1, 0.0, 128),  # single valid slot
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=lambda c: f"T{c[1]}h{c[2]}kv{c[3]}vl{c[5]}")
def test_decode_attention_vs_ref(case):
    B, T, H, KV, hd, vl, softcap, bk = case
    ks = jax.random.split(jax.random.fold_in(KEY, T + B + H), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, T, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, T, KV, hd), jnp.bfloat16)
    got = ops.decode_attention(q, k, v, vl, softcap=softcap, block_k=bk, interpret=True)
    want = ref.decode_attention_reference(q, k, v, vl, softcap=softcap)
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=3e-2, rtol=3e-2)


TICK_CONSTS = dict(t1=0.90, t2=0.97, t1_buf=0.02, t2_buf=0.02,
                   lp_t1=0.85, lp_t2=0.70, hp_t2=0.85, brake_freq=0.50,
                   p0_srv_w=180.0, k_lp_w=300.0, k_hp_w=150.0,
                   lp_share=0.6, gamma=1.6, n_servers=24.0,
                   power_scale=1.10)

TICK_CASES = [
    # (N, T, R, block_members, oob, brake, esc, power_scale)
    (8, 96, 2, 8, 20, 3, 25, 1.10),
    (5, 96, 2, 8, 20, 3, 25, 1.10),   # N not a block multiple (padding)
    (13, 64, 3, 4, 20, 3, 25, 1.18),  # hot: brakes fire
    (3, 48, 1, 8, 5, 2, 4, 1.05),     # short ring, fast escalation
    (16, 32, 2, 16, 20, 3, 25, 0.95), # cool: mostly uncapped
]


@pytest.mark.parametrize("case", TICK_CASES,
                         ids=lambda c: f"n{c[0]}t{c[1]}r{c[2]}b{c[3]}ps{c[7]}")
def test_polca_tick_vs_ref(case):
    """Pallas tick kernel vs the shared-step lax.scan reference: power plane
    to 1e-6 relative, brake/frequency planes bit-identical (float64)."""
    from repro.kernels.tick import TickConsts

    N, T, R, bm, oob, brake, esc, ps = case
    ring_depth = max(oob, brake) + 1
    consts = TickConsts(**{**TICK_CONSTS, "power_scale": ps})
    with jax.experimental.enable_x64():
        rng = np.random.default_rng(N * 1000 + T)
        occ = jnp.asarray(rng.uniform(0.3, 1.0, (N, T, R)))
        bscale = jnp.asarray(rng.uniform(0.9, 1.0, (T, R)))
        row_budget = jnp.asarray(
            consts.n_servers * (consts.p0_srv_w + 0.8 * consts.k_lp_w)
            * np.ones(R))
        got = ops.polca_tick(occ, bscale, row_budget, consts=consts,
                             oob_ticks=oob, brake_ticks=brake,
                             ring_depth=ring_depth, esc=esc,
                             block_members=bm, interpret=True)
        want = ref.polca_tick_reference(occ, bscale, row_budget, consts,
                                        oob_ticks=oob, brake_ticks=brake,
                                        ring_depth=ring_depth, esc=esc)
    np.testing.assert_array_equal(np.asarray(got["fire"]),
                                  np.asarray(want["fire"]))
    np.testing.assert_array_equal(np.asarray(got["n_brakes"]),
                                  np.asarray(want["n_brakes"]))
    for k in ("f_lp", "f_hp"):
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)
    np.testing.assert_allclose(np.asarray(got["row_w"]),
                               np.asarray(want["row_w"]),
                               rtol=1e-6, atol=0.0)


def test_polca_tick_brakes_actually_fire():
    """The hot case must exercise the brake path (otherwise the parity above
    proves nothing about rings/latches)."""
    from repro.kernels.tick import TickConsts

    consts = TickConsts(**{**TICK_CONSTS, "power_scale": 1.30})
    with jax.experimental.enable_x64():
        occ = jnp.ones((4, 64, 2)) * 0.98
        out = ops.polca_tick(occ, jnp.ones((64, 2)),
                             jnp.full(2, consts.n_servers * 250.0),
                             consts=consts, oob_ticks=20, brake_ticks=3,
                             ring_depth=21, esc=25, interpret=True)
    assert int(np.asarray(out["n_brakes"]).sum()) > 0


def test_flash_matches_model_xla_path():
    """Kernel and the model's XLA attention path agree on identical inputs."""
    from repro.models.attention import _chunk_scores, _make_mask
    from repro.configs import smoke_config

    cfg = smoke_config("llama3.2-1b")
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    q, k, v = _qkv(99, B, S, S, H, KV, hd, jnp.float32)
    mask = _make_mask(jnp.arange(S, dtype=jnp.int32), S, causal=True, window=0)
    xla = _chunk_scores(cfg, q, k, v, mask)
    kern = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    np.testing.assert_allclose(np.float32(xla), np.float32(kern), atol=3e-5, rtol=3e-5)
