"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.key(0)


def _qkv(i, B, Sq, Skv, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, i), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    return q, k, v


FLASH_CASES = [
    # (B, Sq, Skv, H, KV, hd, dtype, causal, window, softcap, bq, bk)
    (2, 128, 128, 4, 2, 64, jnp.bfloat16, True, 0, 0.0, 64, 64),
    (2, 128, 128, 4, 2, 64, jnp.float32, True, 0, 0.0, 64, 64),
    (1, 256, 256, 8, 8, 64, jnp.bfloat16, True, 64, 0.0, 64, 64),
    (1, 256, 256, 8, 4, 64, jnp.bfloat16, True, 100, 0.0, 64, 32),
    (1, 128, 128, 4, 1, 128, jnp.bfloat16, True, 0, 50.0, 64, 64),
    (1, 128, 128, 4, 1, 128, jnp.float32, True, 0, 30.0, 32, 64),
    (2, 64, 192, 4, 2, 64, jnp.bfloat16, True, 0, 0.0, 64, 64),  # q_offset
    (1, 128, 128, 2, 2, 32, jnp.float32, False, 0, 0.0, 64, 64),  # bidir
    (1, 64, 64, 16, 2, 64, jnp.bfloat16, True, 0, 0.0, 64, 64),  # G=8
    (1, 256, 256, 4, 4, 256, jnp.bfloat16, True, 128, 30.0, 128, 128),  # gemma2-like
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: f"{c[1]}x{c[2]}h{c[3]}kv{c[4]}d{c[5]}{np.dtype(c[6]).name}c{int(c[7])}w{c[8]}s{c[9]}")
def test_flash_attention_vs_ref(case):
    B, Sq, Skv, H, KV, hd, dtype, causal, window, softcap, bq, bk = case
    q, k, v = _qkv(hash(case[:6]) % 1000, B, Sq, Skv, H, KV, hd, dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=Skv - Sq,
                              block_q=bq, block_k=bk, interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, window=window, softcap=softcap)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=tol, rtol=tol)


DECODE_CASES = [
    # (B, T, H, KV, hd, valid_len, softcap, bk)
    (2, 512, 8, 2, 64, 300, 0.0, 128),
    (1, 1024, 4, 4, 128, 1024, 0.0, 256),
    (3, 512, 16, 8, 64, 17, 0.0, 128),
    (1, 256, 4, 1, 64, 128, 50.0, 64),
    (2, 512, 2, 2, 256, 511, 0.0, 512),
    (1, 128, 32, 4, 64, 1, 0.0, 128),  # single valid slot
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=lambda c: f"T{c[1]}h{c[2]}kv{c[3]}vl{c[5]}")
def test_decode_attention_vs_ref(case):
    B, T, H, KV, hd, vl, softcap, bk = case
    ks = jax.random.split(jax.random.fold_in(KEY, T + B + H), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, T, KV, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, T, KV, hd), jnp.bfloat16)
    got = ops.decode_attention(q, k, v, vl, softcap=softcap, block_k=bk, interpret=True)
    want = ref.decode_attention_reference(q, k, v, vl, softcap=softcap)
    np.testing.assert_allclose(np.float32(got), np.float32(want), atol=3e-2, rtol=3e-2)


def test_flash_matches_model_xla_path():
    """Kernel and the model's XLA attention path agree on identical inputs."""
    from repro.models.attention import _chunk_scores, _make_mask
    from repro.configs import smoke_config

    cfg = smoke_config("llama3.2-1b")
    B, S, H, KV, hd = 2, 128, 4, 2, 16
    q, k, v = _qkv(99, B, S, S, H, KV, hd, jnp.float32)
    mask = _make_mask(jnp.arange(S, dtype=jnp.int32), S, causal=True, window=0)
    xla = _chunk_scores(cfg, q, k, v, mask)
    kern = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    np.testing.assert_allclose(np.float32(xla), np.float32(kern), atol=3e-5, rtol=3e-5)
