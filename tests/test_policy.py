"""Hypothesis property tests on Algorithm 1 (the controller's invariants)."""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # real hypothesis in CI

from repro.core.policy import CapCommand, NoCap, OneThreshold, PolcaPolicy
from repro.core.power_model import FREQ_BRAKE, FREQ_UNCAPPED


powers = st.lists(st.floats(min_value=0.0, max_value=1.3,
                            allow_nan=False, allow_infinity=False),
                  min_size=1, max_size=300)


def _replay(policy, ps):
    lp, hp = FREQ_UNCAPPED, FREQ_UNCAPPED
    states = []
    for p in ps:
        for cmd in policy.step(p):
            if cmd.lp_freq is not None:
                lp = cmd.lp_freq
            if cmd.hp_freq is not None:
                hp = cmd.hp_freq
        states.append((p, lp, hp, policy.braked if hasattr(policy, "braked") else False))
    return states


@given(powers)
@settings(max_examples=200, deadline=None)
def test_overload_always_brakes(ps):
    """P > 1.0 must trigger the powerbrake path immediately (safety)."""
    pol = PolcaPolicy()
    for p in ps:
        cmds = pol.step(p)
        if p > 1.0:
            assert pol.braked
            assert pol.n_brakes >= 1


@given(powers)
@settings(max_examples=200, deadline=None)
def test_lp_always_capped_at_least_as_hard_as_hp(ps):
    """Priority ordering: LP frequency <= HP frequency at every instant."""
    pol = PolcaPolicy()
    for p, lp, hp, _ in _replay(pol, ps):
        assert lp <= hp + 1e-12


@given(powers)
@settings(max_examples=200, deadline=None)
def test_below_uncap_threshold_eventually_uncapped(ps):
    """Sustained low power (below T1 - buffer) must fully uncap."""
    pol = PolcaPolicy()
    _replay(pol, ps)
    states = _replay(pol, [pol.t1 - pol.t1_buffer - 0.02] * 3)
    _, lp, hp, braked = states[-1]
    assert lp == FREQ_UNCAPPED and hp == FREQ_UNCAPPED and not braked


@given(powers)
@settings(max_examples=200, deadline=None)
def test_no_cap_below_t1(ps):
    """The controller never caps while power has always been below T1."""
    pol = PolcaPolicy()
    for p in ps:
        if p > pol.t1:
            break
        cmds = pol.step(p)
        assert not any(c.lp_freq not in (None, FREQ_UNCAPPED) for c in cmds)


@given(powers, st.floats(min_value=0.7, max_value=0.95),
       st.floats(min_value=0.01, max_value=0.1))
@settings(max_examples=100, deadline=None)
def test_hysteresis_no_flapping(ps, t1, buf):
    """Constant power inside the hysteresis band produces no new commands
    after the first response (no cap/uncap oscillation)."""
    pol = PolcaPolicy(t1=t1, t2=min(0.99, t1 + 0.09), t1_buffer=buf, t2_buffer=buf)
    p_hold = t1 - buf / 2  # inside the band: above uncap point, below T1
    pol.step(t1 + 0.01)  # trigger T1 cap
    pol.step(p_hold)
    for _ in range(20):
        assert pol.step(p_hold) == []


@given(powers)
@settings(max_examples=100, deadline=None)
def test_brake_count_monotone_and_bounded(ps):
    pol = PolcaPolicy()
    prev = 0
    overloads = 0
    in_overload = False
    for p in ps:
        pol.step(p)
        assert pol.n_brakes >= prev
        prev = pol.n_brakes
        if p > 1.0 and not in_overload:
            overloads += 1
            in_overload = True
        elif p <= 1.0:
            in_overload = False
    assert pol.n_brakes <= overloads


@given(powers)
@settings(max_examples=100, deadline=None)
def test_baselines_brake_on_overload(ps):
    for mk in (lambda: OneThreshold(cap_hp=False), lambda: OneThreshold(cap_hp=True),
               NoCap):
        pol = mk()
        for p in ps:
            pol.step(p)
            if p > 1.0:
                assert pol.braked


def test_algorithm1_trace():
    """Deterministic walk through the Algorithm-1 state machine."""
    pol = PolcaPolicy(t1=0.80, t2=0.89, escalation_ticks=1)
    assert pol.step(0.5) == []
    # cross T1: LP capped to base frequency
    (c,) = pol.step(0.82)
    assert c.lp_freq == pol.lp_freq_t1 and c.hp_freq is None
    # cross T2: LP capped harder first
    (c,) = pol.step(0.90)
    assert c.lp_freq == pol.lp_freq_t2
    # still above T2: HP capped next
    (c,) = pol.step(0.90)
    assert c.hp_freq == pol.hp_freq_t2
    # overload: brake
    (c,) = pol.step(1.01)
    assert c.brake and c.lp_freq == FREQ_BRAKE
    # recover below T2 buffer: back toward T1 mode
    cmds = pol.step(0.83)
    assert any(c.reason.startswith("brake-release") for c in cmds)
    assert any(c.hp_freq == FREQ_UNCAPPED for c in cmds)
    # fully recover
    cmds = pol.step(0.70)
    assert any(c.lp_freq == FREQ_UNCAPPED for c in cmds)
    assert pol.n_brakes == 1
