"""Streaming window aggregation: P² quantiles, EWMA slope, tumbling and
sliding windows, and the FleetStream composite (repro.obs.stream)."""

import math

import numpy as np
import pytest

from repro.obs.stream import (
    OOB_HORIZON_S,
    EwmaSlope,
    FleetStream,
    P2Quantile,
    SlidingCounter,
    TumblingWindow,
)


# ------------------------------------------------------------- P2Quantile

def test_p2_exact_for_first_five():
    d = P2Quantile(0.5)
    for x in (5.0, 1.0, 3.0, 2.0, 4.0):
        d.observe(x)
    assert d.value() == 3.0  # exact median of {1..5}


def test_p2_nan_before_any_observation():
    assert math.isnan(P2Quantile(0.9).value())


def test_p2_rejects_degenerate_quantile():
    with pytest.raises(ValueError):
        P2Quantile(0.0)
    with pytest.raises(ValueError):
        P2Quantile(1.0)


@pytest.mark.parametrize("q", [0.5, 0.9, 0.99])
def test_p2_tracks_numpy_percentile(q):
    rng = np.random.default_rng(7)
    xs = rng.normal(10.0, 3.0, size=5000)
    d = P2Quantile(q)
    for x in xs:
        d.observe(float(x))
    want = float(np.percentile(xs, 100.0 * q))
    # P² is an estimator: a few percent of the spread is the contract
    assert abs(d.value() - want) < 0.15 * xs.std()


def test_p2_deterministic():
    xs = [math.sin(i * 0.7) * 5.0 + i * 0.01 for i in range(500)]
    d1, d2 = P2Quantile(0.9), P2Quantile(0.9)
    for x in xs:
        d1.observe(x)
        d2.observe(x)
    assert d1.value() == d2.value()


# -------------------------------------------------------------- EwmaSlope

def test_ewma_constant_series_projects_flat():
    e = EwmaSlope()
    for i in range(50):
        e.observe(2.0 * i, 0.8)
    assert e.projected() == pytest.approx(0.8, abs=1e-9)
    assert e.slope == pytest.approx(0.0, abs=1e-9)


def test_ewma_ramp_projects_ahead():
    e = EwmaSlope(horizon_s=40.0)
    slope = 0.001  # frac per second
    for i in range(200):
        e.observe(2.0 * i, 0.5 + slope * 2.0 * i)
    # projection looks one OOB horizon past the level
    assert e.projected() > e.level
    assert e.projected() == pytest.approx(e.level + e.slope * 40.0)
    assert e.slope == pytest.approx(slope, rel=0.15)


def test_ewma_duplicate_tick_ignored():
    e = EwmaSlope()
    e.observe(0.0, 1.0)
    e.observe(2.0, 2.0)
    level, slope = e.level, e.slope
    e.observe(2.0, 99.0)  # dt == 0: dropped
    assert (e.level, e.slope) == (level, slope)


def test_ewma_nan_before_first_observation():
    assert math.isnan(EwmaSlope().projected())


def test_ewma_rejects_bad_smoothing():
    with pytest.raises(ValueError):
        EwmaSlope(alpha=0.0)
    with pytest.raises(ValueError):
        EwmaSlope(beta=1.5)


# --------------------------------------------------------- TumblingWindow

def test_tumbling_window_closes_on_boundary():
    w = TumblingWindow(60.0, quantiles=(0.5,))
    assert w.observe(0.0, 1.0) is None
    assert w.observe(30.0, 3.0) is None
    closed = w.observe(60.0, 100.0)  # lands in the next window
    assert closed is not None and closed is w.last
    assert closed.t_start == 0.0 and closed.t_end == 60.0
    assert closed.count == 2
    assert closed.mean == 2.0
    assert (closed.minimum, closed.maximum) == (1.0, 3.0)
    assert closed.quantile(0.5) == pytest.approx(1.0)  # exact phase, n=2
    assert w.live_count == 1  # the 100.0 observation


def test_window_stats_unknown_quantile_raises():
    w = TumblingWindow(10.0, quantiles=(0.5,))
    w.observe(0.0, 1.0)
    closed = w.observe(10.0, 2.0)
    with pytest.raises(KeyError):
        closed.quantile(0.99)


def test_tumbling_window_rejects_bad_width():
    with pytest.raises(ValueError):
        TumblingWindow(0.0)


# --------------------------------------------------------- SlidingCounter

def test_sliding_counter_rolls_off():
    c = SlidingCounter(width_s=6.0, tick_s=2.0)  # 3 slots
    assert not c.filled
    for x in (1.0, 2.0, 3.0):
        c.push(x)
    assert c.filled and c.total == 6.0
    c.push(10.0)  # evicts the 1.0
    assert c.total == 15.0


def test_sliding_counter_rejects_bad_sizes():
    with pytest.raises(ValueError):
        SlidingCounter(0.0, 2.0)
    with pytest.raises(ValueError):
        SlidingCounter(60.0, 0.0)


# ------------------------------------------------------------ FleetStream

def _feed(st, t, fracs, braked, shed=0, offered=0):
    st.observe(t, np.asarray(fracs, dtype=float),
               np.asarray(braked, dtype=bool),
               shed_total=shed, offered_total=offered)


def test_fleet_stream_brake_edges_and_deltas():
    st = FleetStream(tick_s=2.0)
    edges = st.sliding("brake_edges", 6.0)
    shed = st.sliding("shed", 6.0)
    _feed(st, 2.0, [0.5, 0.6, 0.55], [False, True], shed=0, offered=10)
    assert st.brake_edges_tick == 1  # first tick: braked rows count as edges
    _feed(st, 4.0, [0.5, 0.6, 0.55], [True, False], shed=3, offered=20)
    assert st.brake_edges_tick == 2  # both rows flipped
    assert st.shed_tick == 3 and st.offered_tick == 10
    assert edges.total == 3.0
    assert shed.total == 3.0


def test_fleet_stream_tracks_all_nodes_by_default():
    st = FleetStream(tick_s=2.0)
    _feed(st, 2.0, [0.1, 0.2, 0.3], [False])
    assert sorted(st.node_windows) == [0, 1, 2]


def test_fleet_stream_window_nodes_opt_out():
    st = FleetStream(tick_s=2.0, window_nodes=())
    _feed(st, 2.0, [0.1, 0.2, 0.3], [False])
    assert st.node_windows == {}
    # instantaneous state still live
    assert st.node_frac[-1] == 0.3


def test_fleet_stream_window_nodes_negative_index():
    st = FleetStream(tick_s=2.0, window_nodes=(-1,))
    _feed(st, 2.0, [0.1, 0.2, 0.9], [False])
    assert sorted(st.node_windows) == [2]
    assert st.node_windows[2].live_count == 1


def test_fleet_stream_root_slope_projection():
    st = FleetStream(tick_s=2.0, horizon_s=OOB_HORIZON_S)
    assert math.isnan(st.projected_root_frac())
    for i in range(100):
        _feed(st, 2.0 * (i + 1), [0.0, 0.5 + 0.001 * 2.0 * i], [False])
    # rising root fraction: the projection leads the instantaneous value
    assert st.projected_root_frac() > float(st.node_frac[-1])


def test_fleet_stream_unknown_channel_rejected():
    st = FleetStream(tick_s=2.0)
    with pytest.raises(KeyError):
        st.sliding("nope", 60.0)
