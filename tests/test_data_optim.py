"""Data pipeline determinism, optimizers, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.optim import make_optimizer
from repro.optim.compression import compress_grads, init_error_feedback
from repro.models.param import ParamSpec, init_params, tree_map_specs


def test_pipeline_step_addressable_determinism():
    cfg = smoke_config("llama3.2-1b")
    p1 = SyntheticTokenPipeline(cfg, DataConfig(4, 32, seed=9))
    p2 = SyntheticTokenPipeline(cfg, DataConfig(4, 32, seed=9))
    for step in (0, 7, 123):
        a, b = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(p1.batch_at(1)["tokens"], p1.batch_at(2)["tokens"])


def _quadratic_losses(opt_name, steps=120):
    opt = make_optimizer(opt_name, lr=0.05, weight_decay=0.0)
    target = jnp.asarray([[1.0, -2.0], [0.5, 3.0]])
    specs = {"w": ParamSpec((2, 2), (None, None))}
    params = {"w": jnp.zeros((2, 2))}
    state = init_params(opt.init_specs(specs), jax.random.key(0))

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    losses = []
    for _ in range(steps):
        l, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(g, state, params)
        losses.append(float(l))
    return losses


def test_adamw_converges():
    ls = _quadratic_losses("adamw")
    assert ls[-1] < 1e-2 * ls[0]


def test_adafactor_converges():
    ls = _quadratic_losses("adafactor")
    assert ls[-1] < 5e-2 * ls[0]


def test_grad_compression_error_feedback():
    """int8 + error feedback: the ACCUMULATED compressed sum tracks the true
    sum (residuals don't build up)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    ef = init_error_feedback(g_true)
    acc_hat = jnp.zeros((64, 64))
    acc_true = jnp.zeros((64, 64))
    for i in range(20):
        g = {"w": g_true["w"] * (1 + 0.1 * i)}
        g_hat, ef = compress_grads(g, ef)
        acc_hat += g_hat["w"]
        acc_true += g["w"]
    rel = float(jnp.linalg.norm(acc_hat - acc_true) / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel
    # and a single step is within int8 quantization error
    g_hat, _ = compress_grads(g_true, init_error_feedback(g_true))
    err = float(jnp.max(jnp.abs(g_hat["w"] - g_true["w"])))
    assert err <= float(jnp.max(jnp.abs(g_true["w"]))) / 127.0 + 1e-6
