"""MoE expert-parallel correctness: shard_map + ragged_dot dispatch vs a dense
reference (every expert applied to every token, combined by router weight)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.mesh import set_mesh
from repro.models import moe
from repro.models.param import init_params
from repro.launch.mesh import make_local_mesh

KEY = jax.random.key(0)


def dense_moe_reference(cfg, p, x):
    """O(T*E) reference: compute all experts densely, combine by top-k weight.
    Reconstructs the logical [E, D, F] weights from the slot layout."""
    E, k, D, F = cfg.moe_num_experts, cfg.moe_top_k, cfg.d_model, cfg.moe_d_ff
    slots = p["wg"].shape[0]
    f_shards = slots // E
    Fc = F // f_shards

    def unslot(w, transpose=False):
        # slot s = (expert s//f_shards, chunk s%f_shards)
        if not transpose:  # [slots, D, Fc] -> [E, D, F]
            return np.concatenate(
                [np.concatenate([np.asarray(w[e * f_shards + c]) for c in range(f_shards)],
                                axis=-1)[None] for e in range(E)], axis=0)
        # wd_: [slots, Fc, D] -> [E, F, D]
        return np.concatenate(
            [np.concatenate([np.asarray(w[e * f_shards + c]) for c in range(f_shards)],
                            axis=0)[None] for e in range(E)], axis=0)

    wg, wu = unslot(p["wg"]), unslot(p["wu"])
    wd = unslot(p["wd_"], transpose=True)
    T = x.shape[0] * x.shape[1]
    xf = np.asarray(x, np.float32).reshape(T, D)
    logits = xf @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    topi = np.argsort(-probs, axis=-1)[:, :k]
    topw = np.take_along_axis(probs, topi, axis=-1)
    topw /= topw.sum(-1, keepdims=True)
    out = np.zeros((T, D), np.float32)
    for e in range(E):
        h = xf @ wg[e]
        u = xf @ wu[e]
        y = (h * (1 / (1 + np.exp(-h)))) * u @ wd[e]
        w_e = np.where(topi == e, topw, 0.0).sum(-1)
        out += w_e[:, None] * y
    return out.reshape(x.shape)


@pytest.mark.parametrize("n_model,E", [(1, 4), (2, 4), (2, 8), (2, 2)])
def test_moe_matches_dense_reference(n_model, E):
    n_dev = len(jax.devices())
    if n_model > n_dev:
        pytest.skip(f"needs {n_model} devices")
    cfg = smoke_config("mixtral-8x7b").replace(
        moe_num_experts=E, moe_top_k=2, moe_capacity_factor=8.0,  # no drops
        dtype="float32", param_dtype="float32")
    mesh = make_local_mesh(1, n_model)
    p = init_params(moe.moe_specs(cfg, n_model), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, E), (2, 8, cfg.d_model)) * 0.5
    with set_mesh(mesh):
        got = jax.jit(lambda pp, xx: moe.moe_apply(
            cfg, pp, xx, mesh=mesh, batch_spec=None, gather_axes=()))(p, x)
    want = dense_moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_moe_capacity_drops_bounded():
    """With cf=1.0 some tokens may drop, but output must stay finite and the
    kept fraction must be >= 1/k (the top-1 expert at least mostly kept)."""
    cfg = smoke_config("mixtral-8x7b").replace(
        moe_num_experts=4, moe_top_k=2, moe_capacity_factor=1.0,
        dtype="float32", param_dtype="float32")
    mesh = make_local_mesh(1, 1)
    p = init_params(moe.moe_specs(cfg, 1), KEY)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model))
    with set_mesh(mesh):
        out = moe.moe_apply(cfg, p, x, mesh=mesh, batch_spec=None, gather_axes=())
    assert np.isfinite(np.asarray(out)).all()


def test_moe_layout():
    assert moe.moe_layout(smoke_config("mixtral-8x7b").replace(moe_num_experts=8), 16) \
        == (8, 2, 1, 16)
    assert moe.moe_layout(smoke_config("kimi-k2-1t-a32b").replace(moe_num_experts=384), 16) \
        == (16, 1, 24, 384)
    assert moe.moe_layout(smoke_config("mixtral-8x7b").replace(moe_num_experts=16), 16) \
        == (16, 1, 1, 16)


def test_aux_loss_balanced_router_is_minimal():
    """A uniform router gives aux loss ~= 1 (the Switch lower bound)."""
    cfg = smoke_config("mixtral-8x7b").replace(
        moe_num_experts=4, moe_top_k=2, dtype="float32", param_dtype="float32")
    p = init_params(moe.moe_specs(cfg, 1), KEY)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # perfectly uniform
    x = jax.random.normal(KEY, (2, 64, cfg.d_model))
    loss = float(moe.moe_aux_loss(cfg, p, x))
    assert abs(loss - 1.0) < 0.05


@pytest.mark.parametrize("n_dev_needed,batch_sharded", [(1, False), (2, True), (2, False)])
def test_token_routed_matches_dense_reference(n_dev_needed, batch_sharded):
    """Serve-time token-routed EP (experts resident mesh-wide) == dense ref."""
    if n_dev_needed > len(jax.devices()):
        pytest.skip("needs more devices")
    cfg = smoke_config("mixtral-8x7b").replace(
        moe_num_experts=4, moe_top_k=2, moe_capacity_factor=8.0,
        dtype="float32", param_dtype="float32")
    # EP domain = data x model
    mesh = make_local_mesh(n_dev_needed, 1) if batch_sharded else \
        make_local_mesh(1, n_dev_needed)
    ep = n_dev_needed
    p = init_params(moe.moe_specs(cfg, ep), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 8, cfg.d_model)) * 0.5
    bspec = ("data",) if batch_sharded else None
    with set_mesh(mesh):
        got = jax.jit(lambda pp, xx: moe.moe_apply_token_routed(
            cfg, pp, xx, mesh=mesh, batch_spec=bspec))(p, x)
    want = dense_moe_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)
