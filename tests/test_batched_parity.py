"""Differential-testing oracle harness for the batched ensemble engine.

DESIGN.md §15: the jax jit/vmap/`lax.scan` device program in
``provisioning/batched.py`` must reproduce the numpy tick oracle (which
drives the *real* ``PolcaPolicy``/``PredictivePolcaPolicy`` objects) exactly
— brake-tick sets bit-identical, power series within 1e-6 relative error,
planner decisions identical. Scenarios are property-sampled across the
generator family x hierarchy shape x policy x fault timeline axes; the
shared helpers live in ``tests/conftest.py``.

Durations are deliberately short (0.5 h = 900 ticks) so each drawn example
stays fast while still crossing T1/T2 and (at high ``power_scale``) the
brake threshold; every example still runs the full two-engine round trip.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # real hypothesis in CI
from conftest import (
    PARITY_GENERATORS,
    PARITY_POWER_RTOL,
    assert_engine_parity,
    parity_scenario,
    run_both_engines,
)

from repro.chaos.faults import FaultEvent, FaultSpec
from repro.experiments.scenario import HierarchySpec
from repro.provisioning.batched import (
    lower_ensemble,
    run_batched_ensemble,
    run_tick_model,
)
from repro.provisioning.montecarlo import EnsembleSpec, run_ensemble
from repro.provisioning.planner import RiskConstraints, plan_capacity

HALF_HOUR = 1800.0

generators = st.sampled_from(PARITY_GENERATORS)
occ_hot = st.floats(min_value=0.85, max_value=0.99)
scales_hot = st.floats(min_value=1.05, max_value=1.30)
seeds = st.integers(min_value=0, max_value=10_000)


# ---------------------------------------------------------------------------
# the oracle contract, property-sampled across scenario axes
# ---------------------------------------------------------------------------

@given(generators, occ_hot, scales_hot, seeds)
@settings(max_examples=6, deadline=None)
def test_brake_set_equality_across_generators(gen, occ, scale, seed0):
    """Brake-tick sets are BIT-identical for every generator family."""
    sc = parity_scenario(generator=gen, occ_peak=occ, power_scale=scale,
                         duration_s=HALF_HOUR)
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2, seed0=seed0)
    assert np.array_equal(oracle.brake_fire, jaxed.brake_fire)
    np.testing.assert_array_equal(oracle.n_brakes, jaxed.n_brakes)


@given(generators, occ_hot, seeds)
@settings(max_examples=6, deadline=None)
def test_power_series_within_tolerance(gen, occ, seed0):
    """Full power matrices (total, per-row) within 1e-6 relative error."""
    sc = parity_scenario(generator=gen, occ_peak=occ, duration_s=HALF_HOUR)
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2, seed0=seed0)
    np.testing.assert_allclose(jaxed.total_frac, oracle.total_frac,
                               rtol=PARITY_POWER_RTOL, atol=0.0)
    np.testing.assert_allclose(jaxed.row_w, oracle.row_w,
                               rtol=PARITY_POWER_RTOL, atol=0.0)


@given(generators, occ_hot, scales_hot)
@settings(max_examples=4, deadline=None)
def test_full_contract_parity(gen, occ, scale):
    """The whole oracle contract in one sweep (peaks, means, SLO impacts)."""
    sc = parity_scenario(generator=gen, occ_peak=occ, power_scale=scale,
                         duration_s=HALF_HOUR)
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2)
    assert_engine_parity(oracle, jaxed)


@given(generators, occ_hot, scales_hot, seeds)
@settings(max_examples=4, deadline=None)
def test_predictive_policy_parity(gen, occ, scale, seed0):
    """PredictivePolcaPolicy (EWMA window + 40 s OOB slope extrapolation +
    informed escalation) carried in scan state matches the real policy."""
    sc = parity_scenario(generator=gen, occ_peak=occ, power_scale=scale,
                         duration_s=HALF_HOUR, policy="polca-predictive")
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2, seed0=seed0)
    assert_engine_parity(oracle, jaxed)


@given(st.sampled_from([(2, 2), (2, 3), (3, 2)]), generators, seeds)
@settings(max_examples=4, deadline=None)
def test_hierarchy_node_fold_parity(shape, gen, seed0):
    """Hierarchy folds (segment-sum matmuls over the node matrix) match the
    oracle, and the site fold conserves the row total on both engines."""
    n_rows = shape[0] * shape[1]
    sc = parity_scenario(generator=gen, n_rows=n_rows, occ_peak=0.93,
                         duration_s=HALF_HOUR,
                         hierarchy=HierarchySpec(shape=shape,
                                                 budget_fracs={"0": 0.85}))
    model, oracle, jaxed = run_both_engines(sc, n_seeds=2, seed0=seed0)
    assert_engine_parity(oracle, jaxed)
    site = model.node_names.index("site")
    for run in (oracle, jaxed):
        np.testing.assert_allclose(run.node_w[:, :, site],
                                   run.row_w.sum(axis=2), rtol=1e-9)


@given(st.floats(min_value=0.5, max_value=0.9),
       st.integers(min_value=200, max_value=1100),
       st.booleans(), seeds)
@settings(max_examples=4, deadline=None)
def test_fault_timeline_parity(factor, t_fault, ramp, seed0):
    """Random fault timelines (interior derate with/without ramp, row
    crash/revive, site demand response) lower identically on both engines."""
    faults = FaultSpec((
        FaultEvent("node-derate", t=float(t_fault), node="pdu1",
                   factor=factor, until=float(t_fault + 600),
                   ramp_s=120.0 if ramp else 0.0),
        FaultEvent("row-crash", t=300.0, row=1),
        FaultEvent("row-revive", t=900.0, row=1),
        FaultEvent("site-demand-response", t=1200.0, factor=0.9,
                   until=1600.0),
    ))
    sc = parity_scenario(n_rows=4, occ_peak=0.95, duration_s=HALF_HOUR,
                         hierarchy=HierarchySpec(shape=(2, 2)), faults=faults)
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2, seed0=seed0)
    assert_engine_parity(oracle, jaxed)


# ---------------------------------------------------------------------------
# determinism + invariance properties
# ---------------------------------------------------------------------------

@given(generators, seeds)
@settings(max_examples=4, deadline=None)
def test_seed_determinism(gen, seed0):
    """Same spec -> bit-identical lowering and bit-identical jax results on
    repeat runs; a different seed0 changes the sampled occupancy."""
    sc = parity_scenario(generator=gen, duration_s=HALF_HOUR)
    spec = EnsembleSpec(sc, n_seeds=2, seed0=seed0)
    m1, mem1, _ = lower_ensemble(spec)
    m2, mem2, _ = lower_ensemble(spec)
    np.testing.assert_array_equal(m1.occ60, m2.occ60)
    np.testing.assert_array_equal(m1.alive, m2.alive)
    np.testing.assert_array_equal(m1.budget_scale, m2.budget_scale)
    r1 = run_tick_model(m1, mem1, engine="jax")
    r2 = run_tick_model(m2, mem2, engine="jax")
    np.testing.assert_array_equal(r1.total_frac, r2.total_frac)
    np.testing.assert_array_equal(r1.brake_fire, r2.brake_fire)
    m3, _, _ = lower_ensemble(EnsembleSpec(sc, n_seeds=2, seed0=seed0 + 77))
    assert not np.array_equal(m1.occ60, m3.occ60)


@given(generators, seeds)
@settings(max_examples=3, deadline=None)
def test_member_batch_invariance(gen, seed0):
    """vmap independence: member m's series is bit-identical whether it runs
    in a batch of 4 or alone (no cross-member leakage in the device
    program)."""
    sc = parity_scenario(generator=gen, occ_peak=0.95, duration_s=HALF_HOUR)
    model, members, _ = lower_ensemble(EnsembleSpec(sc, n_seeds=4,
                                                    seed0=seed0))
    full = run_tick_model(model, members, engine="jax")
    for m in (0, 3):
        import dataclasses
        solo_model = dataclasses.replace(model, n_members=1,
                                         occ60=model.occ60[m:m + 1],
                                         seeds=model.seeds[m:m + 1])
        solo = run_tick_model(solo_model, [members[m]], engine="jax")
        np.testing.assert_array_equal(solo.total_frac[0], full.total_frac[m])
        np.testing.assert_array_equal(solo.brake_fire[0], full.brake_fire[m])
        np.testing.assert_array_equal(solo.impacts_lp[0], full.impacts_lp[m])


def test_lowering_rejects_routed_and_short_scenarios():
    from repro.experiments.scenario import RoutingSpec

    sc = parity_scenario(duration_s=HALF_HOUR)
    routed = sc.with_(routing=RoutingSpec(router="round-robin"))
    with pytest.raises(ValueError, match="engine='numpy'"):
        lower_ensemble(EnsembleSpec(routed, n_seeds=2))
    with pytest.raises(ValueError, match="duration"):
        lower_ensemble(EnsembleSpec(sc.with_(duration_s=60.0), n_seeds=2))


# ---------------------------------------------------------------------------
# EnsembleResult statistic parity + planner decisions
# ---------------------------------------------------------------------------

@given(generators, occ_hot, seeds)
@settings(max_examples=3, deadline=None)
def test_ensemble_result_statistic_parity(gen, occ, seed0):
    """run_ensemble(engine='jax') and the tick oracle produce matching
    EnsembleResult statistics end to end (summary dict, CDFs, CVaRs)."""
    sc = parity_scenario(generator=gen, occ_peak=occ, duration_s=HALF_HOUR)
    spec = EnsembleSpec(sc, n_seeds=4, seed0=seed0)
    a = run_ensemble(spec, engine="jax")
    b = run_ensemble(spec, engine="batched-numpy")
    np.testing.assert_array_equal(a.brake_counts, b.brake_counts)
    np.testing.assert_allclose(a.peak_fracs, b.peak_fracs,
                               rtol=PARITY_POWER_RTOL)
    np.testing.assert_allclose(a.mean_fracs, b.mean_fracs,
                               rtol=PARITY_POWER_RTOL)
    np.testing.assert_allclose(a.power_frac, b.power_frac,
                               rtol=PARITY_POWER_RTOL)
    sa, sb = a.summary(), b.summary()
    assert sa.keys() == sb.keys()
    for k in sa:
        np.testing.assert_allclose(sa[k], sb[k], rtol=1e-6, atol=1e-9,
                                   err_msg=f"summary[{k}] differs")
    for alpha in (0.0, 0.5, 0.75):
        np.testing.assert_allclose(a.brake_cvar(alpha), b.brake_cvar(alpha),
                                   rtol=1e-9, atol=0.0)
        np.testing.assert_allclose(a.slo_cvar("low", alpha),
                                   b.slo_cvar("low", alpha),
                                   rtol=1e-6, atol=1e-12)


def test_planner_decisions_identical_across_engines():
    """plan_capacity lands on the same safe_added_servers with the same
    per-probe feasibility verdicts on both batched engines."""
    sc = parity_scenario(occ_peak=0.95, duration_s=HALF_HOUR,
                         n_provisioned=10, added_frac=0.0)
    cons = RiskConstraints(max_brakes=0, max_slo_violation_prob=1.0,
                           slo_cvar_alpha=0.5, max_slo_cvar=2.0,
                           slo_cvar_priority="low")
    plans = {eng: plan_capacity(sc, n_seeds=4, seed0=42, engine=eng,
                                constraints=cons, max_added_frac=0.4)
             for eng in ("jax", "batched-numpy")}
    a, b = plans["jax"], plans["batched-numpy"]
    assert a.safe_added_servers == b.safe_added_servers
    assert [(p.added_servers, p.feasible) for p in a.probes] == \
        [(p.added_servers, p.feasible) for p in b.probes]
    for pa, pb in zip(a.probes, b.probes):
        np.testing.assert_allclose(pa.brake_prob, pb.brake_prob)
        np.testing.assert_allclose(pa.slo_cvar, pb.slo_cvar, rtol=1e-6)


def test_brakes_actually_fire_and_match():
    """The harness demonstrably covers the brake path: at power_scale=1.30
    the fleet must brake, and the brake-tick sets still match bit-for-bit."""
    sc = parity_scenario(occ_peak=0.99, power_scale=1.30,
                         duration_s=HALF_HOUR)
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2)
    assert oracle.n_brakes.sum() > 0, "scenario failed to exercise brakes"
    assert np.array_equal(oracle.brake_fire, jaxed.brake_fire)
    assert_engine_parity(oracle, jaxed)


def test_quiet_scenario_is_quiet_on_both_engines():
    """Low occupancy: no brakes, no caps biting, ~zero SLO impact — and the
    engines agree exactly."""
    sc = parity_scenario(occ_peak=0.35, power_scale=0.9,
                         duration_s=HALF_HOUR)
    _, oracle, jaxed = run_both_engines(sc, n_seeds=2)
    for run in (oracle, jaxed):
        assert run.n_brakes.sum() == 0
        assert run.peak_frac.max() < 1.0
        assert np.abs(run.impacts_hp).max() < 1e-9
    assert_engine_parity(oracle, jaxed)


# ---------------------------------------------------------------------------
# dense tails
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dense_tail_10k_members():
    """10^4-member tail smoke: the jax engine completes a full ensemble in
    one device program and its statistics are sane. (The same tail is
    PASS-gated with throughput in benchmarks/batched_engine.py.)"""
    sc = parity_scenario(occ_peak=0.97, power_scale=1.15,
                         duration_s=HALF_HOUR)
    res = run_batched_ensemble(EnsembleSpec(sc, n_seeds=10_000, seed0=1),
                               engine="jax", keep_series=False)
    assert res.n_members == 10_000
    assert res.power_frac.size == 0  # series dropped above the cell limit
    assert np.isfinite(res.peak_fracs).all()
    assert 0.0 <= res.brake_prob() <= 1.0
    assert res.brake_cvar(0.999) >= res.brake_cvar(0.9) >= res.brake_cvar(0.0)
    tail = res.slo_cvar("low", 0.999)
    assert np.isfinite(tail)
